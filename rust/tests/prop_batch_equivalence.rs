//! Property tests for the batched distance engine: on every metric
//! space, `dist_batch` / `nearest_batch` / `min_update` must agree with
//! scalar `dist` loops, and every bulk query must charge exactly
//! |pts|·|centers| distance evaluations to the work counter.
//!
//! Agreement tolerances: `dist_batch` is the f64 reference path on every
//! space, so it must match scalar `dist` to 1e-12 (it is in fact the
//! same arithmetic). `nearest_batch` is exact too except on the dense
//! Euclidean space, whose cache-tiled scan compares distances in f32 and
//! may resolve near-ties differently — there the distances must agree to
//! f32 precision and the reported winner must be self-consistent to
//! 1e-12 (the winner's distance is recomputed in f64 by contract).

use std::sync::Arc;

use mrcoreset::data::strings::StringClusterSpec;
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::metric::counter;
use mrcoreset::metric::counting::CountingSpace;
use mrcoreset::metric::dense::{ChebyshevSpace, EuclideanSpace, ManhattanSpace};
use mrcoreset::metric::extra::HammingSpace;
use mrcoreset::metric::levenshtein::StringSpace;
use mrcoreset::metric::MetricSpace;
use mrcoreset::prop_assert;
use mrcoreset::util::prop::check;
use mrcoreset::util::rng::Rng;

/// A space under test plus whether its nearest_batch path is exact
/// (f64 end-to-end) or f32-tiled (Euclidean).
struct Case {
    space: Box<dyn MetricSpace>,
    exact_nearest: bool,
}

fn cases(rng: &mut Rng) -> Vec<Case> {
    let n = 30 + rng.below(120);
    let d = 1 + rng.below(6);
    let (data, _) = GaussianMixtureSpec {
        n,
        d,
        k: 1 + rng.below(4),
        spread: 1.0 + rng.f64() * 30.0,
        outlier_frac: 0.0,
        seed: rng.next_u64(),
    }
    .generate();
    let shared = Arc::new(data);
    let (strs, _) = StringClusterSpec {
        n,
        clusters: 1 + rng.below(5),
        base_len: 6 + rng.below(14),
        max_edits: rng.below(5),
        seed: rng.next_u64(),
    }
    .generate();
    let codes: Vec<Vec<u8>> = (0..n)
        .map(|i| (0..8).map(|b| ((i >> b) & 1) as u8 + rng.below(2) as u8).collect())
        .collect();
    vec![
        Case { space: Box::new(EuclideanSpace::new(shared.clone())), exact_nearest: false },
        Case { space: Box::new(ManhattanSpace::new(shared.clone())), exact_nearest: true },
        Case { space: Box::new(ChebyshevSpace::new(shared)), exact_nearest: true },
        Case { space: Box::new(StringSpace::new(strs)), exact_nearest: true },
        Case { space: Box::new(HammingSpace::new(codes)), exact_nearest: true },
    ]
}

fn pick_queries(rng: &mut Rng, n: usize) -> (Vec<u32>, Vec<u32>) {
    let np = 1 + rng.below(n);
    let pts: Vec<u32> = (0..np).map(|_| rng.below(n) as u32).collect();
    let k = 1 + rng.below(8.min(n));
    let centers: Vec<u32> = rng.sample_distinct(n, k).into_iter().map(|i| i as u32).collect();
    (pts, centers)
}

#[test]
fn prop_dist_batch_equals_scalar_dist() {
    check("dist-batch-equivalence", 0xBA7C, 20, |rng| {
        for case in cases(rng) {
            let space = case.space.as_ref();
            let n = space.n_points();
            let (pts, centers) = pick_queries(rng, n);
            let mut out = vec![0.0f64; pts.len()];
            for &c in &centers {
                space.dist_batch(&pts, c, &mut out);
                for (i, &p) in pts.iter().enumerate() {
                    let want = space.dist(p, c);
                    prop_assert!(
                        (out[i] - want).abs() <= 1e-12,
                        "{}: dist_batch[{i}] = {} vs dist = {want}",
                        space.name(),
                        out[i]
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_nearest_batch_equals_scalar_loop() {
    check("nearest-batch-equivalence", 0x4EA2, 20, |rng| {
        for case in cases(rng) {
            let space = case.space.as_ref();
            let n = space.n_points();
            let (pts, centers) = pick_queries(rng, n);
            let a = space.nearest_batch(&pts, &centers);
            for (i, &p) in pts.iter().enumerate() {
                let want =
                    centers.iter().map(|&c| space.dist(p, c)).fold(f64::INFINITY, f64::min);
                let tol = if case.exact_nearest { 1e-12 } else { 1e-6 * (1.0 + want) };
                prop_assert!(
                    (a.dist[i] - want).abs() <= tol,
                    "{}: nearest_batch dist[{i}] = {} vs scalar min {want}",
                    space.name(),
                    a.dist[i]
                );
                // winner self-consistency is exact on every space
                let via_idx = space.dist(p, centers[a.idx[i] as usize]);
                prop_assert!(
                    (a.dist[i] - via_idx).abs() <= 1e-12,
                    "{}: dist[{i}] inconsistent with reported winner",
                    space.name()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_min_update_equals_scalar_fold() {
    check("min-update-equivalence", 0x31FD, 20, |rng| {
        for case in cases(rng) {
            let space = case.space.as_ref();
            let n = space.n_points();
            let (pts, centers) = pick_queries(rng, n);
            let mut cur = vec![f64::INFINITY; pts.len()];
            let mut want = vec![f64::INFINITY; pts.len()];
            for &c in &centers {
                space.min_update(&pts, c, &mut cur);
                for (i, &p) in pts.iter().enumerate() {
                    let d = space.dist(p, c);
                    if d < want[i] {
                        want[i] = d;
                    }
                }
            }
            let tol = if case.exact_nearest { 1e-12 } else { 1e-6 };
            for i in 0..pts.len() {
                prop_assert!(
                    (cur[i] - want[i]).abs() <= tol * (1.0 + want[i]),
                    "{}: min_update[{i}] = {} vs {}",
                    space.name(),
                    cur[i],
                    want[i]
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bulk_queries_charge_point_center_pairs() {
    check("dist-eval-accounting", 0xACC7, 20, |rng| {
        for case in cases(rng) {
            let space = case.space.as_ref();
            let n = space.n_points();
            let (pts, centers) = pick_queries(rng, n);
            let (_, e) = counter::counted(|| space.nearest_batch(&pts, &centers));
            prop_assert!(
                e == (pts.len() * centers.len()) as u64,
                "{}: nearest_batch charged {e}, want {}",
                space.name(),
                pts.len() * centers.len()
            );
            let mut out = vec![0.0f64; pts.len()];
            let (_, e) = counter::counted(|| space.dist_batch(&pts, centers[0], &mut out));
            prop_assert!(
                e == pts.len() as u64,
                "{}: dist_batch charged {e}, want {}",
                space.name(),
                pts.len()
            );
            let mut cur = vec![f64::INFINITY; pts.len()];
            let (_, e) = counter::counted(|| space.min_update(&pts, centers[0], &mut cur));
            prop_assert!(
                e == pts.len() as u64,
                "{}: min_update charged {e}, want {}",
                space.name(),
                pts.len()
            );
        }
        Ok(())
    });
}

/// The counting wrapper must delegate bulk queries (keeping the inner
/// space's fast paths) while metering them as pts×centers.
#[test]
fn counting_space_delegates_and_meters_bulk_queries() {
    let (strs, _) = StringClusterSpec { n: 40, ..Default::default() }.generate();
    let inner = StringSpace::new(strs);
    let counting = CountingSpace::new(&inner);
    let pts: Vec<u32> = (0..40).collect();
    let centers = vec![3u32, 17, 31];

    let a = counting.nearest_batch(&pts, &centers);
    assert_eq!(counting.evals(), (40 * 3) as u64);
    let b = inner.nearest_batch(&pts, &centers);
    assert_eq!(a.dist, b.dist);
    assert_eq!(a.idx, b.idx);

    counting.reset();
    let mut out = vec![0.0f64; 40];
    counting.dist_batch(&pts, 7, &mut out);
    assert_eq!(counting.evals(), 40);
    for (i, &p) in pts.iter().enumerate() {
        assert_eq!(out[i], inner.dist(p, 7));
    }
}
