//! Fault-tolerance acceptance suite.
//!
//! Contract 1 (recovery transparency): a seeded fault plan covering
//! every fault kind — reducer panics, spill read/write I/O errors, and
//! shard bit-flips — recovers on BOTH backends at 1 and 8 threads, and
//! the final report JSON and stable trace are bit-identical to the
//! fault-free run once the recovery bookkeeping itself (`attempts`
//! span fields, `faults.*` counters, the report `retries` key) is
//! stripped. Faults must never change *what* was computed.
//!
//! Contract 2 (checkpoint/resume): a checkpointed spill run that dies
//! mid-job (here: a fault site that outlives the retry budget) resumes
//! from the completed-round prefix and finishes with a report
//! bit-identical to an uninterrupted run.

use std::sync::Arc;

use mrcoreset::coordinator::{try_solve_traced, ClusterConfig, RunReport};
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::mapreduce::{ExecutorCfg, FaultPlan};
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::Objective;
use mrcoreset::obs::{self, Event, MemSink, Recorder};

fn mixture(n: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
    let (data, _) =
        GaussianMixtureSpec { n, d: 2, k: 5, seed, ..Default::default() }.generate();
    (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
}

/// Report JSON with the recovery bookkeeping stripped: the `retries`
/// key and every `faults.*` round counter. Everything else — solution,
/// costs, memory/byte peaks, dist_evals, per-round stats — must be
/// byte-identical between a fault-free and a recovered run.
fn scrubbed_report(mut rep: RunReport) -> String {
    rep.retries = 0;
    for r in &mut rep.stats.rounds {
        r.counters.retain(|(k, _)| !k.starts_with("faults."));
    }
    rep.to_json()
}

/// Stable trace lines with the same bookkeeping stripped from reducer
/// spans (`attempts` back to 1, `faults.*` counters dropped).
fn scrubbed_trace(events: Vec<Event>) -> Vec<String> {
    events
        .into_iter()
        .map(|mut e| {
            if let Event::Reducer { attempts, counters, .. } = &mut e {
                *attempts = 1;
                counters.retain(|(k, _)| !k.starts_with("faults."));
            }
            e.stable_json()
        })
        .collect()
}

/// One traced solve; returns (scrubbed report, scrubbed stable trace,
/// raw retries) so callers can assert both transparency and that
/// recovery actually happened.
fn run(
    space: &EuclideanSpace,
    pts: &[u32],
    executor: ExecutorCfg,
    threads: usize,
) -> (String, Vec<String>, u64) {
    let sink = Arc::new(MemSink::new());
    let rec: Arc<dyn Recorder> = sink.clone();
    let mut cfg = ClusterConfig::new(Objective::Median, 5, 0.4);
    cfg.threads = Some(threads);
    cfg.executor = executor;
    let rep = try_solve_traced(space, pts, &cfg, rec).expect("run must recover");
    let retries = rep.retries;
    (scrubbed_report(rep), scrubbed_trace(sink.snapshot()), retries)
}

/// A plan exercising all four fault kinds at sites every run visits
/// (round 0 is the L-way local round; later rounds keep reducer 0).
/// Within an explicit 2-retry budget (recovery is opt-in — the default
/// is zero retries): the worst site fails twice.
fn mixed_plan() -> FaultPlan {
    FaultPlan::parse("read@0.0x2; panic@0.1; flip@1.0; write@2.0").unwrap()
}

#[test]
fn recovered_runs_are_bit_identical_modulo_bookkeeping() {
    let (space, pts) = mixture(2500, 42);
    let (ref_json, ref_trace, ref_retries) =
        run(&space, &pts, ExecutorCfg::in_memory(), 1);
    assert_eq!(ref_retries, 0, "reference run must be fault-free");
    assert!(ref_trace.len() > 5, "expected run/round/reducer events");

    let faulty_mem = || ExecutorCfg::in_memory().with_faults(mixed_plan()).with_retries(2);
    let faulty_spill = || ExecutorCfg::spill().with_faults(mixed_plan()).with_retries(2);
    let variants: [(&str, ExecutorCfg, usize); 4] = [
        ("mem/1", faulty_mem(), 1),
        ("mem/8", faulty_mem(), 8),
        ("spill/1", faulty_spill(), 1),
        ("spill/8", faulty_spill(), 8),
    ];
    for (label, executor, threads) in variants {
        let (json, trace, retries) = run(&space, &pts, executor, threads);
        assert_eq!(retries, 5, "{label}: 5 injected failures -> 5 retries");
        assert_eq!(ref_json, json, "{label}: scrubbed report differs");
        assert_eq!(ref_trace, trace, "{label}: scrubbed stable trace differs");
    }
}

/// Chaos mode: probabilistic faults from a seeded hash are as
/// recoverable and as transparent as pinned sites, and the SAME plan
/// fires at the SAME (round, reducer) sites on both backends.
#[test]
fn chaos_plan_is_backend_invariant_and_transparent() {
    let (space, pts) = mixture(1500, 7);
    let (ref_json, ref_trace, _) = run(&space, &pts, ExecutorCfg::in_memory(), 1);
    let chaos = || FaultPlan::parse("chaos:panic:500:1234; chaos:read:500:77").unwrap();
    let (mem_json, mem_trace, mem_retries) =
        run(&space, &pts, ExecutorCfg::in_memory().with_faults(chaos()).with_retries(2), 8);
    let (sp_json, sp_trace, sp_retries) =
        run(&space, &pts, ExecutorCfg::spill().with_faults(chaos()).with_retries(2), 1);
    assert!(mem_retries > 0, "400 permille over dozens of reducers must fire");
    assert_eq!(mem_retries, sp_retries, "chaos sites must be backend-agnostic");
    assert_eq!(ref_json, mem_json);
    assert_eq!(mem_json, sp_json);
    assert_eq!(ref_trace, mem_trace);
    assert_eq!(mem_trace, sp_trace);
}

#[test]
fn checkpointed_run_killed_mid_job_resumes_bit_identically() {
    let (space, pts) = mixture(1800, 21);
    let ckpt = std::env::temp_dir()
        .join(format!("mrcoreset-ckpt-resume-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&ckpt);

    let cfg_with = |executor: ExecutorCfg| {
        let mut cfg = ClusterConfig::new(Objective::Median, 5, 0.4);
        cfg.threads = Some(2);
        cfg.executor = executor;
        cfg
    };

    // Reference: the same job, uninterrupted, no checkpointing.
    let reference = try_solve_traced(&space, &pts, &cfg_with(ExecutorCfg::spill()), obs::noop())
        .expect("reference run");

    // "Kill" a checkpointed run after round 0: a round-1 fault site
    // that outlives a zero-retry budget aborts the job exactly where a
    // worker crash would, with round 0 already persisted.
    let doomed = cfg_with(
        ExecutorCfg::spill()
            .with_faults(FaultPlan::parse("read@1.0x9").unwrap())
            .with_retries(0)
            .with_checkpoint_dir(ckpt.clone()),
    );
    let err = try_solve_traced(&space, &pts, &doomed, obs::noop())
        .expect_err("the doomed run must die in round 1");
    assert!(err.to_string().contains("injected"), "{err}");
    assert!(
        ckpt.join("round-0.json").is_file(),
        "round 0 must have been checkpointed before the crash"
    );

    // Resume over the same checkpoint dir with a clean plan: round 0
    // replays from disk, the rest executes, and the report matches the
    // uninterrupted run byte for byte.
    let resumed_cfg = cfg_with(ExecutorCfg::spill().with_checkpoint_dir(ckpt.clone()));
    let resumed = try_solve_traced(&space, &pts, &resumed_cfg, obs::noop())
        .expect("resume must complete");
    assert_eq!(
        reference.to_json(),
        resumed.to_json(),
        "resumed report must be bit-identical to the uninterrupted run"
    );
    assert_eq!(reference.dist_evals, resumed.dist_evals);

    // A different job config must NOT be able to consume the
    // checkpoint: the fingerprint check rejects it up front.
    let mut other = cfg_with(ExecutorCfg::spill().with_checkpoint_dir(ckpt.clone()));
    other.k = 4;
    let err = try_solve_traced(&space, &pts, &other, obs::noop())
        .expect_err("fingerprint mismatch must be refused");
    assert!(err.to_string().contains("fingerprint"), "{err}");

    // ...including fields the run label does not carry (--m) ...
    let mut other_m = cfg_with(ExecutorCfg::spill().with_checkpoint_dir(ckpt.clone()));
    other_m.m = Some(7);
    let err = try_solve_traced(&space, &pts, &other_m, obs::noop())
        .expect_err("a changed --m must be refused");
    assert!(err.to_string().contains("fingerprint"), "{err}");

    // ...and a *different dataset of the same size*, which only the
    // content hash can tell apart.
    let (other_space, other_pts) = mixture(1800, 22);
    let err = try_solve_traced(&other_space, &other_pts, &resumed_cfg, obs::noop())
        .expect_err("a different same-size dataset must be refused");
    assert!(err.to_string().contains("fingerprint"), "{err}");

    let _ = std::fs::remove_dir_all(&ckpt);
}
