//! Integration: the full 3-round pipeline across workloads, objectives,
//! partition strategies, and engine on/off — the composition the unit
//! tests can't see.

use std::sync::Arc;

use mrcoreset::algorithms::local_search::{local_search, LocalSearchCfg};
use mrcoreset::algorithms::Instance;
use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::coreset::TlAlgo;
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::data::trace::TraceSpec;
use mrcoreset::mapreduce::PartitionStrategy;
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::{MetricSpace, Objective};
use mrcoreset::runtime::XlaEngine;

fn mixture(n: usize, d: usize, k: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
    let (data, _) = GaussianMixtureSpec { n, d, k, seed, ..Default::default() }.generate();
    (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
}

#[test]
fn both_objectives_all_strategies() {
    let (space, pts) = mixture(3000, 2, 5, 1);
    for obj in [Objective::Median, Objective::Means] {
        for strategy in [
            PartitionStrategy::RoundRobin,
            PartitionStrategy::Contiguous,
            PartitionStrategy::Shuffled(7),
        ] {
            let mut cfg = ClusterConfig::new(obj, 5, 0.5);
            cfg.strategy = strategy;
            let rep = solve(&space, &pts, &cfg);
            assert_eq!(rep.rounds, 3, "{obj} {strategy:?}");
            assert_eq!(rep.solution.centers.len(), 5);
            assert!(rep.full_cost.is_finite() && rep.full_cost > 0.0);
        }
    }
}

#[test]
fn trace_workload_contiguous_partitions() {
    // contiguous partitions of a drifting trace are maximally
    // heterogeneous — the composability lemma (2.7) must still hold
    let (data, _) = TraceSpec { n: 8000, d: 4, sources: 6, ..Default::default() }.generate();
    let space = EuclideanSpace::new(Arc::new(data));
    let pts: Vec<u32> = (0..8000).collect();
    let w = vec![1u64; 8000];
    let seq = local_search(
        &space,
        Objective::Median,
        Instance::new(&pts, &w),
        6,
        None,
        &LocalSearchCfg::default(),
    );
    let mut cfg = ClusterConfig::new(Objective::Median, 6, 0.3);
    cfg.strategy = PartitionStrategy::Contiguous;
    let rep = solve(&space, &pts, &cfg);
    let ratio = rep.full_cost / seq.cost;
    assert!(ratio < 1.4, "heterogeneous partitions: ratio {ratio}");
}

#[test]
fn all_tl_algorithms_end_to_end() {
    let (space, pts) = mixture(2000, 2, 4, 2);
    for tl in [TlAlgo::DppSeeding, TlAlgo::LocalSearch, TlAlgo::Gonzalez] {
        let mut cfg = ClusterConfig::new(Objective::Median, 4, 0.5);
        cfg.tl = tl;
        let rep = solve(&space, &pts, &cfg);
        assert_eq!(rep.solution.centers.len(), 4, "{tl:?}");
    }
}

#[test]
fn engine_and_scalar_agree_on_solution_quality() {
    let Some(engine) = XlaEngine::load_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let (data, _) =
        GaussianMixtureSpec { n: 6000, d: 4, k: 6, seed: 3, ..Default::default() }.generate();
    let shared = Arc::new(data);
    let plain = EuclideanSpace::new(shared.clone());
    let mut engine = engine;
    engine.set_dispatch_threshold(1);
    let fast = EuclideanSpace::with_engine(shared, Arc::new(engine));
    let pts: Vec<u32> = (0..6000).collect();

    let cfg = ClusterConfig::new(Objective::Means, 6, 0.5);
    let rep_plain = solve(&plain, &pts, &cfg);
    let rep_fast = solve(&fast, &pts, &cfg);
    // engine numerics differ at f32 granularity; solutions may diverge but
    // quality must match closely
    let q = rep_fast.full_cost / rep_plain.full_cost;
    assert!((0.8..1.25).contains(&q), "engine/scalar quality ratio {q}");
    assert_eq!(rep_fast.rounds, 3);
}

#[test]
fn distance_accounting_is_nonzero_and_partition_consistent() {
    let (space, pts) = mixture(2000, 2, 4, 9);
    let mut cfg = ClusterConfig::new(Objective::Median, 4, 0.5);
    cfg.l = Some(5);
    let rep = solve(&space, &pts, &cfg);
    assert_eq!(rep.rounds, 3);
    assert!(rep.dist_evals > 0, "3-round solve must report distance work");
    // the job total is exactly the sum of the per-round counts
    let per_round: u64 = rep.stats.rounds.iter().map(|r| r.dist_evals).sum();
    assert_eq!(rep.dist_evals, per_round);
    for r in &rep.stats.rounds {
        assert_eq!(r.dist_evals, r.reducer_dist_evals.iter().sum::<u64>(), "{}", r.name);
    }
    // round 1 runs one reducer per partition, and every partition holds
    // ~n/L points so every reducer must have done distance work
    let r1 = &rep.stats.rounds[0];
    assert_eq!(r1.reducer_dist_evals.len(), 5, "one reducer per partition");
    assert!(r1.reducer_dist_evals.iter().all(|&e| e > 0), "{:?}", r1.reducer_dist_evals);
    // and the human-readable report surfaces the metric
    assert!(rep.summary().contains("dist_evals="), "{}", rep.summary());
}

#[test]
fn eps_controls_accuracy_size_tradeoff() {
    let (space, pts) = mixture(6000, 2, 6, 4);
    let w = vec![1u64; pts.len()];
    let seq = local_search(
        &space,
        Objective::Median,
        Instance::new(&pts, &w),
        6,
        None,
        &LocalSearchCfg::default(),
    );
    let mut sizes = Vec::new();
    let mut ratios = Vec::new();
    for eps in [0.2, 0.9] {
        let rep = solve(&space, &pts, &ClusterConfig::new(Objective::Median, 6, eps));
        sizes.push(rep.coreset_size);
        ratios.push(rep.full_cost / seq.cost);
    }
    assert!(sizes[0] > sizes[1], "smaller eps must give bigger coreset: {sizes:?}");
    // both must be accurate; tighter eps is not allowed to be (much) worse
    assert!(ratios[0] < ratios[1] + 0.15, "ratios {ratios:?}");
}

#[test]
fn weighted_instance_survives_round3() {
    // the coreset instance has non-trivial weights; verify the final
    // centers respect heavy points by construction: plant a dense blob
    let mut rows: Vec<Vec<f32>> = Vec::new();
    for i in 0..3000 {
        rows.push(vec![(i % 60) as f32 * 0.01, ((i / 60) % 50) as f32 * 0.01]);
    }
    // distant small blob
    for _ in 0..30 {
        rows.push(vec![500.0, 500.0]);
    }
    let n = rows.len();
    let space =
        EuclideanSpace::new(Arc::new(mrcoreset::points::VectorData::from_rows(&rows)));
    let pts: Vec<u32> = (0..n as u32).collect();
    let rep = solve(&space, &pts, &ClusterConfig::new(Objective::Means, 2, 0.4));
    // one center must serve the far blob, else its cost explodes
    let far_served = rep
        .solution
        .centers
        .iter()
        .any(|&c| space.dist(c, (n - 1) as u32) < 10.0);
    assert!(far_served, "far blob unserved: centers {:?}", rep.solution.centers);
}
