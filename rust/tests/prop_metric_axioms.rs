//! Property tests: every `MetricSpace` implementation must satisfy the
//! metric axioms (the paper's entire analysis rests on the triangle
//! inequality), and the bulk operations must agree with pointwise dist.

use std::sync::Arc;

use mrcoreset::data::strings::StringClusterSpec;
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::metric::dense::{ChebyshevSpace, EuclideanSpace, ManhattanSpace};
use mrcoreset::metric::levenshtein::StringSpace;
use mrcoreset::metric::MetricSpace;
use mrcoreset::prop_assert;
use mrcoreset::util::prop::check;
use mrcoreset::util::rng::Rng;

fn vector_spaces(rng: &mut Rng) -> Vec<(Box<dyn MetricSpace>, usize)> {
    let n = 20 + rng.below(60);
    let d = 1 + rng.below(6);
    let (data, _) = GaussianMixtureSpec {
        n,
        d,
        k: 1 + rng.below(4),
        spread: rng.f64() * 30.0,
        outlier_frac: 0.0,
        seed: rng.next_u64(),
    }
    .generate();
    let shared = Arc::new(data);
    vec![
        (Box::new(EuclideanSpace::new(shared.clone())) as Box<dyn MetricSpace>, n),
        (Box::new(ManhattanSpace::new(shared.clone())), n),
        (Box::new(ChebyshevSpace::new(shared)), n),
    ]
}

#[test]
fn prop_metric_axioms_vector_spaces() {
    check("metric-axioms", 0xAB1E, 15, |rng| {
        for (space, n) in vector_spaces(rng) {
            for _ in 0..40 {
                let i = rng.below(n) as u32;
                let j = rng.below(n) as u32;
                let k = rng.below(n) as u32;
                let dij = space.dist(i, j);
                prop_assert!(dij >= 0.0, "{}: negative distance", space.name());
                prop_assert!(
                    (dij - space.dist(j, i)).abs() < 1e-9,
                    "{}: asymmetric",
                    space.name()
                );
                prop_assert!(space.dist(i, i) == 0.0, "{}: d(i,i) != 0", space.name());
                let thru = space.dist(i, k) + space.dist(k, j);
                // f32 storage: allow relative slack ~ f32 eps at magnitude
                prop_assert!(
                    dij <= thru + 1e-5 * (1.0 + thru),
                    "{}: triangle violated: d({i},{j})={dij} > {thru}",
                    space.name()
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_levenshtein_axioms() {
    check("levenshtein-axioms", 0x1E57, 8, |rng| {
        let (strs, _) = StringClusterSpec {
            n: 40,
            clusters: 1 + rng.below(6),
            base_len: 6 + rng.below(20),
            max_edits: rng.below(6),
            seed: rng.next_u64(),
        }
        .generate();
        let n = strs.len();
        let space = StringSpace::new(strs);
        for _ in 0..30 {
            let i = rng.below(n) as u32;
            let j = rng.below(n) as u32;
            let k = rng.below(n) as u32;
            prop_assert!(
                (space.dist(i, j) - space.dist(j, i)).abs() < 1e-12,
                "asymmetric edit distance"
            );
            prop_assert!(
                space.dist(i, j) <= space.dist(i, k) + space.dist(k, j) + 1e-12,
                "triangle violated"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_bulk_ops_agree_with_dist() {
    check("bulk-agree", 0xB01C, 12, |rng| {
        for (space, n) in vector_spaces(rng) {
            let pts: Vec<u32> = (0..n as u32).collect();
            let m = 1 + rng.below(8.min(n));
            let centers: Vec<u32> =
                rng.sample_distinct(n, m).into_iter().map(|i| i as u32).collect();
            let a = space.assign(&pts, &centers);
            for (i, &p) in pts.iter().enumerate() {
                let want =
                    centers.iter().map(|&c| space.dist(p, c)).fold(f64::INFINITY, f64::min);
                // the tiled scan runs in f32; winners may differ among
                // centers equidistant within f32 noise
                let tol = 1e-5 * (1.0 + want);
                prop_assert!(
                    (a.dist[i] - want).abs() < tol,
                    "{}: assign dist mismatch at {i}: {} vs {want}",
                    space.name(),
                    a.dist[i]
                );
                let via_idx = space.dist(p, centers[a.idx[i] as usize]);
                prop_assert!(
                    (via_idx - want).abs() < tol,
                    "{}: argmin inconsistent at {i}",
                    space.name()
                );
            }
            // min_update from infinity equals assign dist (same tolerance)
            let mut cur = vec![f64::INFINITY; n];
            for &c in &centers {
                space.min_update(&pts, c, &mut cur);
            }
            for i in 0..n {
                let tol = 1e-5 * (1.0 + a.dist[i]);
                prop_assert!((cur[i] - a.dist[i]).abs() < tol, "min_update mismatch at {i}");
            }
        }
        Ok(())
    });
}
