//! Regression: for a fixed `CoresetConfig::seed` the coreset pipeline is
//! bit-identical across repeated runs AND across simulator thread counts.
//! This holds by construction — reducer outputs are collected in input
//! order and every reducer RNG derives from (seed, partition index)
//! only — but was asserted nowhere, so a scheduling-dependent regression
//! (e.g. a work-stealing reducer RNG) would have slipped through.

use std::sync::Arc;

use mrcoreset::coordinator::{solve, solve_traced, ClusterConfig};
use mrcoreset::obs::{MemSink, Recorder};
use mrcoreset::coreset::{two_round_coreset, CoresetConfig, PipelineOutput};
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::mapreduce::{PartitionStrategy, Simulator};
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::Objective;

fn mixture(n: usize, seed: u64) -> (EuclideanSpace, Vec<u32>) {
    let (data, _) =
        GaussianMixtureSpec { n, d: 3, k: 5, seed, ..Default::default() }.generate();
    (EuclideanSpace::new(Arc::new(data)), (0..n as u32).collect())
}

fn run_pipeline(
    space: &EuclideanSpace,
    pts: &[u32],
    obj: Objective,
    threads: usize,
) -> PipelineOutput {
    let sim = Simulator::new().with_threads(threads);
    let cfg = CoresetConfig { seed: 0xD1CE, ..CoresetConfig::new(5, 0.4) };
    two_round_coreset(space, obj, pts, 6, PartitionStrategy::RoundRobin, &cfg, &sim)
        .expect("pipeline")
}

#[test]
fn two_round_coreset_bit_identical_across_runs_and_threads() {
    let (space, pts) = mixture(3000, 7);
    for obj in [Objective::Median, Objective::Means] {
        // threads=1 twice (run-to-run) and threads=8 (scheduling)
        let reference = run_pipeline(&space, &pts, obj, 1);
        for threads in [1usize, 8] {
            let out = run_pipeline(&space, &pts, obj, threads);
            assert_eq!(
                reference.coreset.indices, out.coreset.indices,
                "{obj} threads={threads}: coreset members differ"
            );
            assert_eq!(
                reference.coreset.weights, out.coreset.weights,
                "{obj} threads={threads}: coreset weights differ"
            );
            // radii and the global tolerance are f64s computed in input
            // order — they must be bit-identical, not merely close
            assert_eq!(reference.radii, out.radii, "{obj} threads={threads}");
            assert_eq!(reference.global_r, out.global_r, "{obj} threads={threads}");
            assert_eq!(reference.part_sizes, out.part_sizes);
        }
    }
}

/// The outlier pipeline inherits the same contract: reducer outputs in
/// input order, RNGs derived from (seed, partition index) only — so the
/// whole (k, z) solve must be bit-identical at 1 vs 8 threads.
#[test]
fn outlier_solve_bit_identical_across_thread_counts() {
    use mrcoreset::data::synth::NoiseSpec;
    let spec =
        GaussianMixtureSpec { n: 1500, d: 2, k: 4, spread: 30.0, seed: 21, ..Default::default() };
    let (data, _) = spec.generate_with_noise(&NoiseSpec {
        count: 30,
        expanse: 10.0,
        offset: 40.0,
        seed: 22,
    });
    let total = data.n() as u32;
    let space = EuclideanSpace::new(Arc::new(data));
    let pts: Vec<u32> = (0..total).collect();
    for obj in [Objective::Median, Objective::Means] {
        let mut cfg1 = ClusterConfig::new(obj, 4, 0.5);
        cfg1.outliers = 30;
        cfg1.threads = Some(1);
        let mut cfg8 = cfg1.clone();
        cfg8.threads = Some(8);
        let a = solve(&space, &pts, &cfg1);
        let b = solve(&space, &pts, &cfg8);
        assert_eq!(a.solution.centers, b.solution.centers, "{obj}");
        assert_eq!(a.solution.cost.to_bits(), b.solution.cost.to_bits(), "{obj}");
        assert_eq!(a.full_cost.to_bits(), b.full_cost.to_bits(), "{obj}");
        assert_eq!(a.robust_full_cost.to_bits(), b.robust_full_cost.to_bits(), "{obj}");
        assert_eq!(a.excluded, b.excluded, "{obj}: excluded sets differ");
        assert_eq!(a.coreset_size, b.coreset_size, "{obj}");
        assert_eq!(a.dist_evals, b.dist_evals, "{obj}");
    }
}

/// Telemetry inherits the determinism contract: with tracing ENABLED,
/// the JSON report and the stable trace lines (wall-clock omitted) must
/// be bit-identical at 1 vs 8 simulator threads — events are emitted by
/// the coordinator in (round, reducer) order, never arrival order.
#[test]
fn traced_solve_identical_reports_and_traces_across_thread_counts() {
    let (space, pts) = mixture(2000, 9);
    let run = |threads: usize| {
        let sink = Arc::new(MemSink::new());
        let rec: Arc<dyn Recorder> = sink.clone();
        let mut cfg = ClusterConfig::new(Objective::Median, 4, 0.5);
        cfg.threads = Some(threads);
        let rep = solve_traced(&space, &pts, &cfg, rec);
        let trace: Vec<String> = sink.snapshot().iter().map(|e| e.stable_json()).collect();
        (rep.to_json(), trace)
    };
    let (json1, trace1) = run(1);
    let (json8, trace8) = run(8);
    assert_eq!(json1, json8, "RunReport::to_json must be thread-count invariant");
    assert!(trace1.len() > 5, "expected run/round/reducer events, got {}", trace1.len());
    assert_eq!(trace1, trace8, "stable trace lines must be bit-identical across thread counts");

    // and tracing must be a pure observer: the untraced solve computes
    // the same report
    let mut cfg = ClusterConfig::new(Objective::Median, 4, 0.5);
    cfg.threads = Some(8);
    let untraced = solve(&space, &pts, &cfg);
    assert_eq!(untraced.to_json(), json8, "tracing must not change the computation");
}

#[test]
fn full_solve_bit_identical_across_thread_counts() {
    let (space, pts) = mixture(2000, 9);
    for obj in [Objective::Median, Objective::Means] {
        let mut cfg1 = ClusterConfig::new(obj, 4, 0.5);
        cfg1.threads = Some(1);
        let mut cfg8 = cfg1.clone();
        cfg8.threads = Some(8);
        let a = solve(&space, &pts, &cfg1);
        let b = solve(&space, &pts, &cfg8);
        assert_eq!(a.solution.centers, b.solution.centers, "{obj}");
        assert_eq!(a.solution.cost.to_bits(), b.solution.cost.to_bits(), "{obj}");
        assert_eq!(a.full_cost.to_bits(), b.full_cost.to_bits(), "{obj}");
        assert_eq!(a.coreset_size, b.coreset_size, "{obj}");
        assert_eq!(a.cw_size, b.cw_size, "{obj}");
        // the work metric is deterministic too: same queries either way
        assert_eq!(a.dist_evals, b.dist_evals, "{obj}");
    }
}
