"""L2 correctness: model entry points vs oracles + AOT contract tests."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _rand(shape, seed, scale=1.0):
    rs = np.random.RandomState(seed)
    return (rs.randn(*shape) * scale).astype(np.float32)


def test_assign_matches_ref():
    x, c = _rand((256, 8), 0), _rand((128, 8), 1)
    dmin, idx = model.assign(jnp.asarray(x), jnp.asarray(c))
    rdmin, ridx = ref.assign_ref(jnp.asarray(x), jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(dmin), np.asarray(rdmin), atol=1e-4, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))


def test_assign_idx_dtype_i32():
    x, c = _rand((256, 4), 2), _rand((128, 4), 3)
    _, idx = model.assign(jnp.asarray(x), jnp.asarray(c))
    assert idx.dtype == jnp.int32


def test_min_update_matches_ref():
    x = _rand((256, 8), 4)
    c = _rand((1, 8), 5)
    cur = np.abs(_rand((256,), 6)) * 10
    (got,) = model.min_update(jnp.asarray(x), jnp.asarray(c), jnp.asarray(cur))
    want = ref.min_update_ref(jnp.asarray(x), jnp.asarray(c[0]), jnp.asarray(cur))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)


def test_min_update_monotone():
    # result never exceeds the running minimum
    x = _rand((256, 8), 7)
    c = _rand((1, 8), 8)
    cur = np.abs(_rand((256,), 9))
    (got,) = model.min_update(jnp.asarray(x), jnp.asarray(c), jnp.asarray(cur))
    assert (np.asarray(got) <= cur + 1e-6).all()


def test_assign_cost_fused_matches_parts():
    x, c = _rand((256, 8), 10), _rand((128, 8), 11)
    w = np.abs(_rand((256,), 12)) + 0.5
    nu, mu, dmin, idx = model.assign_cost(jnp.asarray(x), jnp.asarray(c), jnp.asarray(w))
    rdmin, ridx = ref.assign_ref(jnp.asarray(x), jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(dmin), np.asarray(rdmin), atol=1e-4, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))
    np.testing.assert_allclose(
        float(nu), float(ref.weighted_cost_ref(rdmin, jnp.asarray(w), False)), rtol=1e-4
    )
    np.testing.assert_allclose(
        float(mu), float(ref.weighted_cost_ref(rdmin, jnp.asarray(w), True)), rtol=1e-4
    )


def test_assign_cost_zero_weights_mask_padding():
    # padded rows (w = 0) must not contribute to nu/mu even with garbage coords
    x = _rand((256, 8), 13)
    x[200:] = 1e6  # garbage padding rows
    c = _rand((128, 8), 14)
    w = np.ones(256, np.float32)
    w[200:] = 0.0
    nu, mu, _, _ = model.assign_cost(jnp.asarray(x), jnp.asarray(c), jnp.asarray(w))
    rdmin, _ = ref.assign_ref(jnp.asarray(x[:200]), jnp.asarray(c))
    np.testing.assert_allclose(
        float(nu), float(jnp.sum(jnp.sqrt(rdmin))), rtol=1e-3
    )
    np.testing.assert_allclose(float(mu), float(jnp.sum(rdmin)), rtol=1e-3)


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([1, 4, 64, 256]),
    k=st.sampled_from([1, 2, 128]),
    d=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_assign_hypothesis(n, k, d, seed):
    x, c = _rand((n, d), seed), _rand((k, d), seed + 1)
    dmin, idx = model.assign(jnp.asarray(x), jnp.asarray(c))
    rdmin, ridx = ref.assign_ref(jnp.asarray(x), jnp.asarray(c))
    np.testing.assert_allclose(np.asarray(dmin), np.asarray(rdmin), atol=1e-4, rtol=1e-4)
    # ties can differ only where distances are equal within tolerance
    same = np.asarray(idx) == np.asarray(ridx)
    if not same.all():
        bad = ~same
        d2 = np.asarray(ref.pairwise_sq_ref(jnp.asarray(x), jnp.asarray(c)))
        np.testing.assert_allclose(
            d2[bad, np.asarray(idx)[bad]], d2[bad, np.asarray(ridx)[bad]], rtol=1e-5
        )
