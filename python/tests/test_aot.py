"""AOT contract: the lowered HLO text is parseable, stable, and complete.

These tests protect the rust side: they validate the exact interchange
format (HLO text with a tuple root), entry parameter layouts, and the
manifest schema — the things the rust runtime parses blind.
"""

import os
import re
import subprocess
import sys

import pytest

from compile.aot import lower_assign_cost, lower_min_update


def test_assign_cost_hlo_structure():
    text = lower_assign_cost(256, 4, 128)
    assert text.startswith("HloModule")
    # entry layout lists the three params and the 4-tuple result
    assert "f32[256,4]" in text
    assert "f32[128,4]" in text
    assert "(f32[], f32[], f32[256]" in text.replace(" ", "")[:400] or "f32[]" in text
    # tuple root (return_tuple=True)
    assert "tuple(" in text.replace(") ", ")")


def test_min_update_hlo_structure():
    text = lower_min_update(256, 4)
    assert text.startswith("HloModule")
    assert "f32[256,4]" in text
    assert "f32[1,4]" in text
    assert "f32[256]" in text


def test_lowering_deterministic():
    a = lower_min_update(256, 16)
    b = lower_min_update(256, 16)
    assert a == b, "AOT lowering must be reproducible for artifact caching"


def test_no_mosaic_custom_call():
    # interpret=True must keep the kernel in plain HLO (CPU-executable);
    # a tpu_custom_call would mean a Mosaic lowering leaked through.
    text = lower_assign_cost(256, 4, 128)
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


@pytest.mark.slow
def test_quick_aot_build(tmp_path):
    # end-to-end: the module CLI writes artifacts + manifest
    env = dict(os.environ)
    repo_py = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path), "--quick"],
        cwd=repo_py,
        check=True,
    )
    manifest = (tmp_path / "manifest.txt").read_text().strip().splitlines()
    assert manifest[0].startswith("#")
    rows = [l.split() for l in manifest[1:]]
    assert all(len(r) == 5 for r in rows)
    kinds = {r[0] for r in rows}
    assert kinds == {"assign_cost", "min_update"}
    for r in rows:
        f = tmp_path / r[4]
        assert f.exists() and f.read_text().startswith("HloModule")
        n, d, k = map(int, r[1:4])
        assert re.search(rf"f32\[{n},{d}\]", f.read_text())
