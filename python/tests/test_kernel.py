"""L1 correctness: Pallas pairwise kernel vs the pure-jnp oracle.

hypothesis sweeps shapes and value regimes; fixed cases pin the edge
behaviours the rust runtime relies on (clamping, tie-breaking, padding).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.pairwise import pairwise_sq
from compile.kernels import ref

# Block-divisible shape grid the AOT buckets use. The kernel requires
# n % block_n == 0 and k % block_k == 0 (blocks shrink to fit small inputs).
NS = [1, 2, 8, 256, 512]
KS = [1, 2, 128, 256]
DS = [1, 2, 3, 4, 16, 64]


def _rand(shape, seed, scale=1.0, dtype=np.float32):
    rs = np.random.RandomState(seed)
    return (rs.randn(*shape) * scale).astype(dtype)


def _check(x, c, atol=1e-4, rtol=1e-4):
    got = np.asarray(pairwise_sq(jnp.asarray(x), jnp.asarray(c)))
    want = np.asarray(ref.pairwise_sq_ref(jnp.asarray(x), jnp.asarray(c)))
    np.testing.assert_allclose(got, want, atol=atol, rtol=rtol)
    assert (got >= 0.0).all(), "squared distances must be clamped at 0"


@pytest.mark.parametrize("n", NS)
@pytest.mark.parametrize("k", KS)
def test_pairwise_shape_grid(n, k):
    d = 8
    _check(_rand((n, d), seed=n * 1000 + k), _rand((k, d), seed=k))


@pytest.mark.parametrize("d", DS)
def test_pairwise_feature_dims(d):
    _check(_rand((256, d), seed=d), _rand((128, d), seed=d + 1))


def test_identical_points_zero_distance():
    x = _rand((256, 16), seed=3)
    got = np.asarray(pairwise_sq(jnp.asarray(x), jnp.asarray(x[:128])))
    # diagonal of the first 128 rows is exact 0 after clamping
    np.testing.assert_allclose(np.diag(got[:128]), 0.0, atol=1e-5)


def test_translation_near_invariance():
    # d(x+t, c+t) == d(x, c) up to float error
    x = _rand((256, 8), seed=4)
    c = _rand((128, 8), seed=5)
    t = np.full((1, 8), 7.25, np.float32)
    a = np.asarray(pairwise_sq(jnp.asarray(x), jnp.asarray(c)))
    b = np.asarray(pairwise_sq(jnp.asarray(x + t), jnp.asarray(c + t)))
    np.testing.assert_allclose(a, b, atol=1e-3, rtol=1e-3)


def test_large_magnitudes_no_nan():
    x = _rand((256, 4), seed=6, scale=1e6)
    c = _rand((128, 4), seed=7, scale=1e6)
    got = np.asarray(pairwise_sq(jnp.asarray(x), jnp.asarray(c)))
    assert np.isfinite(got).all()
    _check(x, c, atol=1e8, rtol=1e-3)  # relative check dominates at this scale


def test_pad_center_value_never_wins():
    # centers at PAD_CENTER_VALUE are farther than any real center
    from compile.model import PAD_CENTER_VALUE

    x = _rand((256, 4), seed=8, scale=100.0)
    c = _rand((128, 4), seed=9, scale=100.0)
    c[64:] = PAD_CENTER_VALUE
    got = np.asarray(pairwise_sq(jnp.asarray(x), jnp.asarray(c)))
    assert (np.argmin(got, axis=1) < 64).all()


@settings(max_examples=40, deadline=None)
@given(
    n=st.sampled_from([1, 2, 4, 64, 256]),
    k=st.sampled_from([1, 2, 64, 128]),
    d=st.integers(min_value=1, max_value=24),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pairwise_hypothesis_sweep(n, k, d, scale, seed):
    x = _rand((n, d), seed=seed, scale=scale)
    c = _rand((k, d), seed=seed + 1, scale=scale)
    got = np.asarray(pairwise_sq(jnp.asarray(x), jnp.asarray(c)))
    want = np.asarray(ref.pairwise_sq_ref(jnp.asarray(x), jnp.asarray(c)))
    np.testing.assert_allclose(got, want, atol=1e-4 * scale * scale * d, rtol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    bn=st.sampled_from([32, 64, 128, 256]),
    bk=st.sampled_from([16, 32, 128]),
)
def test_pairwise_block_size_invariance(bn, bk):
    # the tiling must not change the numbers
    x = _rand((256, 8), seed=10)
    c = _rand((128, 8), seed=11)
    got = np.asarray(pairwise_sq(jnp.asarray(x), jnp.asarray(c), block_n=bn, block_k=bk))
    base = np.asarray(pairwise_sq(jnp.asarray(x), jnp.asarray(c)))
    np.testing.assert_allclose(got, base, atol=1e-5, rtol=1e-5)
