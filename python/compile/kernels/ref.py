"""Pure-jnp oracles for the Pallas kernels and the L2 model entry points.

These are the correctness ground truth: simple, obviously-right broadcast
formulations with no tiling. pytest asserts kernel == ref across a
hypothesis-driven sweep of shapes and value regimes.
"""

from __future__ import annotations

import jax.numpy as jnp


def pairwise_sq_ref(x, c):
    """(n, d), (k, d) -> (n, k) squared Euclidean distances, clamped at 0."""
    diff = x[:, None, :] - c[None, :, :]
    return jnp.maximum(jnp.sum(diff * diff, axis=-1), 0.0)


def assign_ref(x, c):
    """Nearest-center assignment: (min squared distance, argmin index)."""
    d2 = pairwise_sq_ref(x, c)
    return jnp.min(d2, axis=1), jnp.argmin(d2, axis=1).astype(jnp.int32)


def min_update_ref(x, c, cur):
    """Elementwise min of current best squared distance and d(x, c)^2.

    x: (n, d), c: (d,) single new center, cur: (n,) current best d^2.
    """
    diff = x - c[None, :]
    d2 = jnp.maximum(jnp.sum(diff * diff, axis=1), 0.0)
    return jnp.minimum(cur, d2)


def weighted_cost_ref(dmin_sq, w, squared):
    """Weighted clustering cost from per-point min squared distances.

    squared=True  -> k-means cost  mu  = sum w_i * d_i^2
    squared=False -> k-median cost nu  = sum w_i * d_i
    """
    d = dmin_sq if squared else jnp.sqrt(dmin_sq)
    return jnp.sum(w * d)
