"""L1 Pallas kernel: blocked squared-Euclidean distance matrix.

The paper's compute hot spot is point<->center distance evaluation
(assignment passes inside CoverWithBalls, k-means++ seeding, local search,
and final clustering). On TPU this is the classic distance-matrix roofline
kernel: expand ||x - c||^2 = ||x||^2 + ||c||^2 - 2 x.c so the dominant term
is a matmul that runs on the MXU; the norms are cheap VPU reductions.

BlockSpec tiles the (n, d) x (k, d) problem into (BN, d) x (BK, d) VMEM
blocks; the full d extent stays resident per block (d is small for
clustering workloads: <= 64 in our buckets, so a (BN=256, BK=128, d=64)
tile set is ~(256*64 + 128*64 + 256*128)*4 B ~= 230 KiB, far inside the
~16 MiB VMEM budget, leaving room for double buffering).

interpret=True is mandatory here: the CPU PJRT plugin cannot execute the
Mosaic custom-call a real TPU lowering would produce. The kernel still
lowers into plain HLO that the rust runtime loads and runs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default VMEM-friendly tile sizes (see module docstring for the budget).
BLOCK_N = 256
BLOCK_K = 128


def _pairwise_sq_kernel(x_ref, c_ref, o_ref):
    """One (BN, BK) output tile: ||x||^2 + ||c||^2 - 2 x c^T, clamped at 0."""
    x = x_ref[...]  # (BN, d) f32 in VMEM
    c = c_ref[...]  # (BK, d) f32 in VMEM
    xn = jnp.sum(x * x, axis=1, keepdims=True)  # (BN, 1)
    cn = jnp.sum(c * c, axis=1)[None, :]  # (1, BK)
    # MXU term: prefer f32 accumulation explicitly.
    xc = jax.lax.dot_general(
        x,
        c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (BN, BK)
    # Clamp: catastrophic cancellation can yield tiny negatives for
    # near-identical points; downstream takes sqrt.
    o_ref[...] = jnp.maximum(xn + cn - 2.0 * xc, 0.0)


@functools.partial(jax.jit, static_argnames=("block_n", "block_k"))
def pairwise_sq(x, c, *, block_n: int = BLOCK_N, block_k: int = BLOCK_K):
    """Squared Euclidean distance matrix via the Pallas kernel.

    x: (n, d) f32, c: (k, d) f32  ->  (n, k) f32, d2[i, j] = ||x_i - c_j||^2.
    n must be divisible by block_n and k by block_k (the AOT buckets
    guarantee this; tests cover the exact-fit grid).
    """
    n, d = x.shape
    k, d2 = c.shape
    assert d == d2, f"feature dims differ: {d} vs {d2}"
    bn = min(block_n, n)
    bk = min(block_k, k)
    assert n % bn == 0 and k % bk == 0, (n, k, bn, bk)
    grid = (n // bn, k // bk)
    return pl.pallas_call(
        _pairwise_sq_kernel,
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bk, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bn, bk), lambda i, j: (i, j)),
        interpret=True,
    )(x, c)
