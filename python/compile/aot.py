"""AOT lowering: JAX (L2) + Pallas (L1) -> HLO text artifacts for rust.

Emits HLO *text* (NOT `lowered.compile().serialize()` or proto bytes): the
image's xla_extension 0.5.1 rejects jax>=0.5 protos with 64-bit instruction
ids, while `HloModuleProto::from_text_file` re-parses text and reassigns
ids cleanly (see /opt/xla-example/README.md).

Artifacts are shape-bucketed: the rust runtime pads a real (n, d, k)
problem up to the smallest bucket that fits, masks padded rows, and uses
PAD_CENTER_VALUE-initialized center slots that can never win an argmin.

Outputs, under --out-dir (default ../artifacts):
  assign_cost_{N}x{D}x{K}.hlo.txt   (x, c, w)       -> (nu, mu, dmin_sq, idx)
  min_update_{N}x{D}.hlo.txt        (x, c1, cur)    -> (new_min,)
  manifest.txt                      one line per artifact (kind n d k file)

Usage: cd python && python -m compile.aot [--out-dir DIR] [--quick]
"""

from __future__ import annotations

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Shape buckets. Clustering blocks are padded up to these; keep the grid
# coarse to bound artifact count (3 * 3 * 4 assign_cost + 3 * 3 min_update).
N_BUCKETS = [256, 1024, 4096, 16384]
D_BUCKETS = [4, 16, 64]
K_BUCKETS = [128, 512, 2048]

QUICK_N = [256, 1024]
QUICK_D = [4, 16]
QUICK_K = [128]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_assign_cost(n: int, d: int, k: int) -> str:
    return to_hlo_text(jax.jit(model.assign_cost).lower(f32(n, d), f32(k, d), f32(n)))


def lower_min_update(n: int, d: int) -> str:
    return to_hlo_text(jax.jit(model.min_update).lower(f32(n, d), f32(1, d), f32(n)))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join("..", "artifacts"))
    ap.add_argument(
        "--quick", action="store_true", help="small bucket set for fast CI builds"
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    ns = QUICK_N if args.quick else N_BUCKETS
    ds = QUICK_D if args.quick else D_BUCKETS
    ks = QUICK_K if args.quick else K_BUCKETS

    manifest = []
    for d in ds:
        for n in ns:
            name = f"min_update_{n}x{d}.hlo.txt"
            path = os.path.join(args.out_dir, name)
            text = lower_min_update(n, d)
            with open(path, "w") as f:
                f.write(text)
            manifest.append(f"min_update {n} {d} 1 {name}")
            print(f"wrote {name} ({len(text)} chars)", file=sys.stderr)
            for k in ks:
                name = f"assign_cost_{n}x{d}x{k}.hlo.txt"
                path = os.path.join(args.out_dir, name)
                text = lower_assign_cost(n, d, k)
                with open(path, "w") as f:
                    f.write(text)
                manifest.append(f"assign_cost {n} {d} {k} {name}")
                print(f"wrote {name} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("# kind n d k file\n")
        f.write("\n".join(manifest) + "\n")
    print(f"{len(manifest)} artifacts -> {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()
