"""L2: JAX compute graphs for the distance hot path, calling the L1 kernel.

Three entry points are AOT-lowered (see aot.py) and executed from the rust
coordinator via PJRT:

  assign(x, c)            -> (dmin_sq (n,) f32, idx (n,) i32)
      nearest-center assignment; used by clustering passes, coreset
      weighting, and cost evaluation. Pallas pairwise kernel inside.

  min_update(x, c, cur)   -> (new_min (n,) f32,)
      one greedy step of CoverWithBalls / k-means++ / Gonzalez: fold a
      single new center into the running min squared distance.

  assign_cost(x, c, w)    -> (nu f32, mu f32, dmin_sq (n,), idx (n,))
      fused assignment + weighted k-median (nu) and k-means (mu) costs,
      avoiding a second pass over the distance matrix.

All inputs are f32; `x` rows beyond the true n are padding (the rust side
masks them out via the returned per-point vectors, and passes w=0 for
padded rows in assign_cost so the scalar costs are already exact).
Padded centers are set to PAD_CENTER_VALUE so they never win an argmin.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels.pairwise import pairwise_sq

# Rust pads unused center slots with this coordinate value; with genuine
# data normalized to O(1e3) magnitudes these are never the argmin.
PAD_CENTER_VALUE = 3.0e18


def assign(x, c):
    """Nearest-center assignment over a block of points."""
    d2 = pairwise_sq(x, c)
    dmin = jnp.min(d2, axis=1)
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    return dmin, idx


def min_update(x, c, cur):
    """Fold one new center (c: (1, d)) into the running min d^2 (cur: (n,))."""
    d2 = pairwise_sq(x, c)[:, 0]
    return (jnp.minimum(cur, d2),)


def assign_cost(x, c, w):
    """Fused assignment + weighted nu/mu costs (w: (n,) f32, 0 for padding)."""
    d2 = pairwise_sq(x, c)
    dmin = jnp.min(d2, axis=1)
    idx = jnp.argmin(d2, axis=1).astype(jnp.int32)
    nu = jnp.sum(w * jnp.sqrt(dmin))
    mu = jnp.sum(w * dmin)
    return nu, mu, dmin, idx
