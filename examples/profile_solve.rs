//! Phase-level timing of the 3-round solve (perf-report substitute).
use mrcoreset::algorithms::Instance;
use mrcoreset::algorithms::local_search::{local_search, LocalSearchCfg};
use mrcoreset::coreset::{cover_with_balls, two_round_coreset, CoresetConfig};
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::mapreduce::{default_l, partition, PartitionStrategy, Simulator};
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::{MetricSpace, Objective};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    let n = 20_000;
    let k = 8;
    let (data, _) = GaussianMixtureSpec { n, d: 4, k, seed: 1, ..Default::default() }.generate();
    let space = EuclideanSpace::new(Arc::new(data));
    let pts: Vec<u32> = (0..n as u32).collect();
    let l = default_l(n, k);
    let cfg = CoresetConfig::new(k, 0.5);

    // two-round pipeline with external timing
    let sim = Simulator::new().with_threads(1); // serialize for clean attribution
    let t0 = Instant::now();
    let out = two_round_coreset(&space, Objective::Median, &pts, l, PartitionStrategy::RoundRobin, &cfg, &sim).expect("pipeline");
    let t_pipe = t0.elapsed();
    let stats = sim.take_stats();
    for r in &stats.rounds { println!("{}: {:.3}s", r.name, r.wall.as_secs_f64()); }
    println!("pipeline total {:.3}s; |C_w|={} |E_w|={}", t_pipe.as_secs_f64(), out.cw_size, out.coreset.len());

    // round-2 internals: assign vs greedy for one partition
    let parts = partition(&pts, l, PartitionStrategy::RoundRobin);
    let cw: Vec<u32> = out.coreset.indices.clone(); // ~|E_w| as stand-in for C_w
    let t1 = Instant::now();
    let a = space.assign(&parts[0], &cw);
    println!("r2 initial assign {}x{}: {:.3}s", parts[0].len(), cw.len(), t1.elapsed().as_secs_f64());
    std::hint::black_box(a);
    let t2 = Instant::now();
    let res = cover_with_balls(&space, &parts[0], &cw, out.global_r.unwrap(), 0.5, 2.0);
    println!("r2 cover_with_balls on partition: {:.3}s (|E_l|={})", t2.elapsed().as_secs_f64(), res.set.len());

    // round 3
    let t3 = Instant::now();
    let inst = Instance::new(&out.coreset.indices, &out.coreset.weights);
    let sol = local_search(&space, Objective::Median, inst, k, None, &LocalSearchCfg::default());
    println!("round-3 local search on {}: {:.3}s cost {}", out.coreset.len(), t3.elapsed().as_secs_f64(), sol.cost);
}
