//! Scaling demo: local memory and coreset size vs input size at the
//! paper's L = ∛(n/k) — the sublinearity that makes the algorithm a
//! MapReduce algorithm (Theorem 3.14).
//!
//!     cargo run --release --example scaling

use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::Objective;
use mrcoreset::util::stats::power_fit;
use std::sync::Arc;

fn main() {
    let k = 8;
    println!("{:>8} {:>4} {:>8} {:>10} {:>10} {:>8}", "n", "L", "|E_w|", "M_L", "M_A", "M_L/n");
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for n in [4_000usize, 8_000, 16_000, 32_000, 64_000] {
        let (data, _) = GaussianMixtureSpec { n, d: 2, k, seed: 9, ..Default::default() }.generate();
        let space = EuclideanSpace::new(Arc::new(data));
        let pts: Vec<u32> = (0..n as u32).collect();
        let rep = solve(&space, &pts, &ClusterConfig::new(Objective::Median, k, 0.6));
        println!(
            "{:>8} {:>4} {:>8} {:>10} {:>10} {:>8.3}",
            n,
            rep.l,
            rep.coreset_size,
            rep.max_local_memory,
            rep.aggregate_memory,
            rep.max_local_memory as f64 / n as f64
        );
        xs.push(n as f64);
        ys.push(rep.max_local_memory as f64);
    }
    let (c, e, r2) = power_fit(&xs, &ys);
    println!("\nfit: M_L ≈ {c:.2} · n^{e:.3} (r²={r2:.4}); theory: exponent ≈ 2/3");
    assert!(e < 0.95, "local memory must grow sublinearly (got n^{e:.3})");
    println!("scaling OK");
}
