//! Out-of-core execution: the same solve under both backends, plus what
//! happens when the per-reducer byte budget is too small.
//!
//!     cargo run --release --example spill_executor
//!
//! The CLI equivalent of the spill run below is
//!
//!     mrcoreset run --n 20000 --executor spill --mem-budget 64k
//!
//! `SpillExecutor` materialises one partition shard at a time from
//! disk-backed spill files, so peak resident bytes stay within a hard
//! budget — and by the byte-parity contract its results are
//! bit-identical to the in-memory backend's.

use std::sync::Arc;

use mrcoreset::coordinator::{solve, try_solve_traced, ClusterConfig};
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::mapreduce::{ExecError, ExecutorCfg};
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::Objective;
use mrcoreset::obs;

fn main() {
    // 1. Data: the usual benign mixture, large enough that a partition
    //    shard is tens of kilobytes.
    let n = 20_000;
    let (data, _) =
        GaussianMixtureSpec { n, d: 2, k: 6, seed: 17, ..Default::default() }.generate();
    let space = EuclideanSpace::new(Arc::new(data));
    let pts: Vec<u32> = (0..n as u32).collect();

    // 2. Reference run, fully in RAM. `max_local_bytes` is the largest
    //    encoded footprint any reducer held at once — the number a real
    //    cluster would have to provision per worker.
    let mut cfg = ClusterConfig::new(Objective::Median, 6, 0.5);
    cfg.executor = ExecutorCfg::in_memory();
    let mem = solve(&space, &pts, &cfg);
    let peak = mem.max_local_bytes;
    println!(
        "in-memory: cost={:.1} peak resident = {peak} B (kernel {})",
        mem.full_cost, mem.kernel
    );

    // 3. The same solve out of core, under a hard budget of exactly the
    //    measured peak. Byte parity means this is the tightest budget
    //    that can work — and it does, bit-identically.
    let mut cfg = ClusterConfig::new(Objective::Median, 6, 0.5);
    cfg.executor = ExecutorCfg::spill().with_budget(peak);
    let spill = solve(&space, &pts, &cfg);
    println!(
        "spill:     cost={:.1} peak resident = {} B (budget {peak} B), \
         {} B written to spill files",
        spill.full_cost,
        spill.max_local_bytes,
        spill.stats.spill_write_bytes()
    );
    assert_eq!(mem.to_json(), spill.to_json(), "backends must agree bit for bit");
    assert!(spill.max_local_bytes <= peak);

    // 4. One byte less and the run must refuse — with a structured
    //    error naming the round, the reducer, and the shortfall, never
    //    an allocator blow-up.
    let mut cfg = ClusterConfig::new(Objective::Median, 6, 0.5);
    cfg.executor = ExecutorCfg::spill().with_budget(peak - 1);
    match try_solve_traced(&space, &pts, &cfg, obs::noop()) {
        Ok(_) => panic!("a budget below the measured peak cannot succeed"),
        Err(ExecError::OverBudget { round, reducer, needed, budget, resident }) => {
            println!(
                "budget {budget} B refused: round {round:?} reducer {reducer} \
                 needed {needed} B with {resident} B already resident"
            );
        }
        Err(e) => panic!("expected an over-budget error, got: {e}"),
    }
}
