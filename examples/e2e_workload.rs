//! End-to-end validation driver (DESIGN.md §3, EXPERIMENTS.md §E2E):
//! run the full stack — L3 rust coordinator → MapReduce simulator →
//! CoverWithBalls coreset → XLA/PJRT distance kernels (L1 Pallas via AOT
//! HLO) → weighted local search — on a realistic 20k-point workload
//! trace, for both k-median and k-means, and report the paper's headline
//! metrics: cost ratio to the sequential α-approximation, round count,
//! local/aggregate memory, coreset size, and wall-clock throughput.
//!
//!     make artifacts && cargo run --release --example e2e_workload

use std::sync::Arc;
use std::time::Instant;

use mrcoreset::algorithms::local_search::{local_search, LocalSearchCfg};
use mrcoreset::algorithms::Instance;
use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::data::trace::TraceSpec;
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::Objective;
use mrcoreset::runtime::XlaEngine;

fn main() {
    let n = 20_000;
    let k = 12;
    let eps = 0.4;

    // Workload: drifting-source trace with bursts and 2% noise — the
    // synthetic stand-in for a production feature log (DESIGN.md §5).
    let (data, _) = TraceSpec { n, d: 4, sources: k, ..Default::default() }.generate();
    println!("workload: trace n={n} d=4 sources={k}");

    let shared = Arc::new(data);
    let engine = XlaEngine::load_default();
    let space = match engine {
        Some(e) => {
            println!(
                "engine: XLA/PJRT loaded ({} artifacts; CPU auto-select keeps the tiled scalar path — see EXPERIMENTS.md §Perf)",
                e.manifest().entries.len()
            );
            EuclideanSpace::with_engine(shared, Arc::new(e))
        }
        None => {
            println!("engine: scalar fallback (run `make artifacts` for the XLA path)");
            EuclideanSpace::new(shared)
        }
    };
    let pts: Vec<u32> = (0..n as u32).collect();

    for obj in [Objective::Median, Objective::Means] {
        println!("\n=== {obj} (k={k}, eps={eps}) ===");

        // sequential reference: strong local search on the full input
        let t0 = Instant::now();
        let w = vec![1u64; n];
        let seq_cfg =
            LocalSearchCfg { max_passes: 60, sample_candidates: 128, ..Default::default() };
        let seq = local_search(&space, obj, Instance::new(&pts, &w), k, None, &seq_cfg);
        let seq_wall = t0.elapsed();

        // the paper's 3-round MapReduce algorithm
        let cfg = ClusterConfig::new(obj, k, eps);
        let rep = solve(&space, &pts, &cfg);

        print!("{}", rep.summary());
        let ratio = rep.full_cost / seq.cost;
        println!("sequential reference: cost={:.1} wall={:.2}s", seq.cost, seq_wall.as_secs_f64());
        println!("HEADLINE cost(MR)/cost(seq) = {ratio:.4}  (theory: α+O(ε) vs α ⇒ ≈ 1+O(ε))");
        println!(
            "throughput: {:.0} points/s end-to-end ({} rounds, M_L={} = {:.1}% of n)",
            n as f64 / rep.wall.as_secs_f64(),
            rep.rounds,
            rep.max_local_memory,
            100.0 * rep.max_local_memory as f64 / n as f64
        );
        assert_eq!(rep.rounds, 3);
        assert!(ratio < 1.5, "MR solution should be close to the sequential reference");
    }
    println!("\nE2E OK");
}
