//! Continuous k-means (§3.1 "Application to the continuous case"):
//! build the 1-round coreset C_w, run weighted Lloyd on it, and compare
//! with Lloyd on the full input — the α+O(ε) continuous guarantee.
//!
//!     cargo run --release --example continuous

use std::sync::Arc;

use mrcoreset::algorithms::lloyd::{continuous_cost, lloyd, ContinuousSolution, LloydCfg};
use mrcoreset::coreset::{one_round_coreset, CoresetConfig};
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::mapreduce::{default_l, PartitionStrategy, Simulator};
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::Objective;

/// best-of-3 restarts: vanilla Lloyd is sensitive to seeding, and the
/// comparison needs a stable reference on both sides.
fn lloyd_best(
    data: &mrcoreset::points::VectorData,
    pts: &[u32],
    w: &[u64],
    k: usize,
) -> ContinuousSolution {
    (0..3)
        .map(|s| lloyd(data, pts, w, k, &LloydCfg { seed: 0xF00D + s, ..Default::default() }))
        .min_by(|a, b| a.cost.partial_cmp(&b.cost).unwrap())
        .unwrap()
}

fn main() {
    let (n, d, k) = (12_000, 4, 8);
    let (data, _) = GaussianMixtureSpec { n, d, k, seed: 3, ..Default::default() }.generate();
    let pts: Vec<u32> = (0..n as u32).collect();
    let unit = vec![1u64; n];

    // reference: weighted Lloyd on the full input
    let full = lloyd_best(&data, &pts, &unit, k);
    println!("full-input Lloyd: cost = {:.1}", full.cost);

    let space = EuclideanSpace::new(Arc::new(data.clone()));
    for eps in [0.2, 0.4, 0.8] {
        let sim = Simulator::new();
        let cfg = CoresetConfig::new(k, eps);
        let out = one_round_coreset(
            &space,
            Objective::Means,
            &pts,
            default_l(n, k),
            PartitionStrategy::RoundRobin,
            &cfg,
            &sim,
        )
        .expect("pipeline");
        let sol = lloyd_best(&data, &out.coreset.indices, &out.coreset.weights, k);
        let cost = continuous_cost(&data, &pts, &unit, &sol.centroids);
        println!(
            "eps={eps:<4} |C_w|={:>6}  Lloyd-on-coreset cost = {:>10.1}  ratio = {:.4}",
            out.coreset.len(),
            cost,
            cost / full.cost
        );
        assert!(cost / full.cost < 1.3, "coreset Lloyd should track full Lloyd");
    }
    println!("continuous OK (1 MapReduce round for the coreset, as §3.1 promises)");
}
