//! Outlier-robust clustering: solve the (k, z) objective on a mixture
//! contaminated with a far uniform noise blob, and compare against the
//! plain z = 0 solver on the same instance.
//!
//!     cargo run --release --example outliers

use std::sync::Arc;

use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::data::synth::{GaussianMixtureSpec, NoiseSpec};
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::{MetricSpace, Objective};
use mrcoreset::outliers::robust_cost_of_dists;

fn main() {
    // 1. Data: 4 tight clusters in a small box, plus 100 uniform noise
    //    points in a far-away blob (the adversarial regime: serving the
    //    blob is worth abandoning a real cluster to a plain solver).
    let n = 5_000;
    let noise = 100;
    let spec =
        GaussianMixtureSpec { n, d: 2, k: 4, spread: 30.0, seed: 42, ..Default::default() };
    let (data, labels) = spec.generate_with_noise(&NoiseSpec {
        count: noise,
        expanse: 10.0,
        offset: 40.0,
        seed: 43,
    });
    let total = data.n();
    let space = EuclideanSpace::new(Arc::new(data));
    let pts: Vec<u32> = (0..total as u32).collect();

    // 2. Robust solve: k-median with z = 100 outliers written off.
    let mut cfg = ClusterConfig::new(Objective::Median, 4, 0.5);
    cfg.outliers = noise;
    let robust = solve(&space, &pts, &cfg);
    print!("{}", robust.summary());

    // 3. Plain solve on the same instance, evaluated under the same
    //    z-excluded objective for a fair comparison.
    let plain = solve(&space, &pts, &ClusterConfig::new(Objective::Median, 4, 0.5));
    let assign = space.assign(&pts, &plain.solution.centers);
    let unit = vec![1u64; pts.len()];
    let plain_robust =
        robust_cost_of_dists(Objective::Median, &assign.dist, &unit, noise as u64);

    println!("\ninlier (z-excluded) objective:");
    println!("  robust solver (z={noise}): {:.1}", robust.robust_full_cost);
    println!("  plain solver  (z=0):    {:.1}", plain_robust.cost);

    // 4. Outlier recall: how many of the written-off points are the
    //    injected noise? (Noise occupies the last `noise` indices.)
    let hits = robust
        .excluded
        .iter()
        .filter(|&&i| labels[i as usize] == u32::MAX)
        .count();
    println!("outlier recall: {hits}/{noise}");
    assert!(robust.robust_full_cost < plain_robust.cost);
}
