//! General metric spaces: k-median over strings with edit distance.
//!
//! This is the paper's raison d'être — the constructions work in ANY
//! metric space (centers ⊆ P), not just R^d. No XLA path exists here;
//! everything runs through the generic `MetricSpace` trait.
//!
//!     cargo run --release --example general_metric

use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::data::strings::StringClusterSpec;
use mrcoreset::metric::levenshtein::StringSpace;
use mrcoreset::metric::Objective;

fn main() {
    // 2000 strings derived from 10 seed strings by ≤4 random edits.
    let spec = StringClusterSpec { n: 2000, clusters: 10, base_len: 24, max_edits: 4, seed: 7 };
    let (strings, labels) = spec.generate();
    println!("workload: {} strings, 10 latent clusters, edit-distance metric", strings.len());

    let space = StringSpace::new(strings);
    let pts: Vec<u32> = (0..2000).collect();

    let cfg = ClusterConfig::new(Objective::Median, 10, 0.5);
    let rep = solve(&space, &pts, &cfg);
    print!("{}", rep.summary());

    // score against the known generation labels: a center's cluster is
    // its seed cluster; count points whose nearest center shares their label
    let assign = mrcoreset::metric::MetricSpace::assign(&space, &pts, &rep.solution.centers);
    let center_labels: Vec<u32> =
        rep.solution.centers.iter().map(|&c| labels[c as usize]).collect();
    let agree = pts
        .iter()
        .enumerate()
        .filter(|(i, _)| center_labels[assign.idx[*i] as usize] == labels[*i])
        .count();
    println!(
        "cluster recovery: {}/{} points assigned to a center from their own latent cluster ({:.1}%)",
        agree,
        pts.len(),
        100.0 * agree as f64 / pts.len() as f64
    );
    println!("centers: {:?}", rep.solution.centers);
    assert_eq!(rep.rounds, 3);
    assert!(agree as f64 / pts.len() as f64 > 0.8, "cluster recovery too low");
    println!("general-metric OK");
}
