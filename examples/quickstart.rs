//! Quickstart: cluster a synthetic point set with the paper's 3-round
//! MapReduce k-median algorithm and inspect the report.
//!
//!     cargo run --release --example quickstart

use std::sync::Arc;

use mrcoreset::coordinator::{solve, ClusterConfig};
use mrcoreset::data::synth::GaussianMixtureSpec;
use mrcoreset::metric::dense::EuclideanSpace;
use mrcoreset::metric::Objective;

fn main() {
    // 1. Data: 10k points in R², 8 well-separated Gaussian clusters.
    let (data, _labels) =
        GaussianMixtureSpec { n: 10_000, d: 2, k: 8, seed: 42, ..Default::default() }.generate();

    // 2. Space: Euclidean metric over the point store. `new` resolves
    //    the distance-kernel backend (cache-blocked by default; see the
    //    `metric::kernel` docs, or pin one with
    //    `EuclideanSpace::with_kernel`). Attach the XLA engine with
    //    `EuclideanSpace::with_engine` — see examples/e2e_workload.rs.
    let space = EuclideanSpace::new(Arc::new(data));
    let pts: Vec<u32> = (0..10_000).collect();

    // 3. Solve: k-median, k=8, precision ε=0.8. Defaults follow §3.4:
    //    L = ∛(n/k) partitions, T_ℓ via k-means++ with 2k oversampling,
    //    final round = weighted local search on the coreset.
    let cfg = ClusterConfig::new(Objective::Median, 8, 0.8);
    let report = solve(&space, &pts, &cfg);

    // 4. Inspect. `report.kernel` records which backend served the
    //    bulk distance queries.
    print!("{}", report.summary());
    println!("kernel: {}", report.kernel);
    assert_eq!(report.rounds, 3);
    println!("\ncenters (point indices): {:?}", report.solution.centers);
    println!(
        "compression: {} points -> |E_w| = {} ({:.1}%)",
        pts.len(),
        report.coreset_size,
        100.0 * report.coreset_size as f64 / pts.len() as f64
    );
}
